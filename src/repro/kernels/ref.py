"""Pure-jnp oracles for every Pallas kernel in this package.

Each `*_ref` is the semantic ground truth: simple, obviously-correct jnp.
Kernel tests sweep shapes/dtypes and `assert_allclose(kernel, ref)`; `ops.py`
also uses these as the CPU fallback path (the dry-run compiles these — same
FLOPs, no TPU-only lowering).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e9


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray, *, out_dtype=None) -> jnp.ndarray:
    out_dtype = out_dtype or a.dtype
    return jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def int8_matmul_ref(xq: jnp.ndarray, wq: jnp.ndarray, x_scale: jnp.ndarray,
                    w_scale: jnp.ndarray) -> jnp.ndarray:
    """INT8 x INT8 -> INT32 accumulate -> FP32 rescale."""
    acc = jnp.matmul(xq.astype(jnp.int32), wq.astype(jnp.int32),
                     preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * (x_scale * w_scale)


def bitmap_spmm_ref(dense_a: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """GraSp oracle: the block-compacted form must equal the dense matmul."""
    return (dense_a @ h).astype(h.dtype)


def bitmap_spmm_block_ref(blocks: jnp.ndarray, block_cols: jnp.ndarray,
                          counts: jnp.ndarray, h: jnp.ndarray, *,
                          block_size: int) -> jnp.ndarray:
    """GraSp ref path ON the compacted form — pure jnp, so it traces under
    jit/vmap with the block structure as a runtime argument (the serving
    plans need exactly that; the old ref densified on the HOST and could
    not see tracers). Same math as the kernel: gather the H row-blocks each
    bitmap entry names, MAC the real ones, mask the padded tail.

    This is still a dense-XLA fallback, not a skip win: every padded list
    entry is fetched and multiplied-by-zero rather than skipped — callers
    that must observe a GraSp dispatch running without the skip grid check
    `ops.bitmap_spmm_mode()` (GraphServe counts it as `backend_fallbacks`).
    """
    rb, max_nnz = block_cols.shape
    bs = block_size
    f = h.shape[1]
    hb = h.reshape(h.shape[0] // bs, bs, f)
    gathered = hb[block_cols]                           # (rb, max_nnz, bs, f)
    blk = blocks.reshape(rb, max_nnz, bs, bs)
    mask = (jnp.arange(max_nnz)[None, :] < counts[:, None]).astype(blocks.dtype)
    return jnp.einsum("rk,rkij,rkjf->rif", mask, blk, gathered
                      ).reshape(rb * bs, f).astype(h.dtype)


def gat_attention_ref(h: jnp.ndarray, alpha_dst: jnp.ndarray,
                      alpha_src: jnp.ndarray, bias_add: jnp.ndarray,
                      *, negative_slope: float = 0.2) -> jnp.ndarray:
    """Fused GAT oracle (EffOp + GrAx1 + GrAx2 dense formulation).

    h: (N, H, F); alpha_dst/alpha_src: (N, H); bias_add: (N, N) 0 / -1e9.
    out[i, hd] = sum_j softmax_j(leaky(ad[i,hd]+as[j,hd]) + bias[i,j]) h[j,hd].
    """
    e = alpha_dst[:, None, :] + alpha_src[None, :, :]            # (N, N, H)
    e = jax.nn.leaky_relu(e, negative_slope=negative_slope)
    e = e + bias_add[:, :, None]
    e = e - jnp.max(e, axis=1, keepdims=True)
    p = jnp.exp(e)
    attn = p / jnp.maximum(p.sum(axis=1, keepdims=True), 1e-12)  # (N, N, H)
    return jnp.einsum("ijh,jhf->ihf", attn, h)


def sage_max_ref(mask01: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """GrAx3 oracle: out[i,f] = max_j mask[i,j] * h[j,f] (h assumed >= 0;
    isolated rows -> 0, matching the paper's DPU max-pool semantics)."""
    prod = mask01[:, :, None] * h[None, :, :]
    return jnp.max(prod, axis=1)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        scale: Optional[float] = None,
                        q_offset: int = 0) -> jnp.ndarray:
    """Exact GQA attention oracle.

    q: (B, Sq, H, D); k, v: (B, Skv, KV, D) with H % KV == 0.
    `q_offset`: absolute position of q[0] (decode: Skv-1 typically).
    `window`: sliding-window size (attend to keys within `window` positions).
    `softcap`: gemma2-style tanh logit soft capping.
    """
    b, sq, hh, d = q.shape
    _, skv, kv, _ = k.shape
    group = hh // kv
    scale = scale if scale is not None else d ** -0.5
    kr = jnp.repeat(k, group, axis=2)
    vr = jnp.repeat(v, group, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kr).astype(jnp.float32) * scale
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    attn = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", attn.astype(vr.dtype), vr)
    return out.astype(q.dtype)
