"""Public kernel entry points with backend routing.

Routing policy (documented in DESIGN.md §6):

  * backend == "tpu"            -> real Pallas kernels (MXU tiling).
  * REPRO_PALLAS_INTERPRET=1    -> Pallas kernels in interpret mode (CPU
                                   correctness validation; what the tests use).
  * otherwise (CPU dry-run)     -> pure-jnp reference path. Same math, same
                                   FLOPs in cost_analysis, no TPU-only lowering
                                   — the multi-pod dry-run compiles this.

Every wrapper pads operands to kernel tile multiples when needed and strips
the padding from the result (NodePad makes this a no-op for graph operands).
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .bitmap_spmm import bitmap_spmm as _bitmap_spmm_kernel
from .block_matmul import block_matmul as _block_matmul
from .flash_attention import flash_attention as _flash_kernel
from .gat_attention import gat_attention as _gat_kernel
from .int8_matmul import int8_matmul as _int8_kernel
from .sage_max import sage_max as _sage_max_kernel


def _mode() -> str:
    if jax.default_backend() == "tpu":
        return "pallas"
    if os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1":
        return "interpret"
    return "ref"


def _pad2(x: jnp.ndarray, m0: int, m1: int) -> jnp.ndarray:
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 == 0 and p1 == 0:
        return x
    return jnp.pad(x, ((0, p0), (0, p1)))


def matmul(a: jnp.ndarray, b: jnp.ndarray, *, out_dtype=None) -> jnp.ndarray:
    """StaGr aggregation backbone: C = A @ B (MXU-tiled on TPU)."""
    mode = _mode()
    if mode == "ref":
        return ref.matmul_ref(a, b, out_dtype=out_dtype)
    m, k = a.shape
    _, n = b.shape
    ap, bp = _pad2(a, 128, 128), _pad2(b, 128, 128)
    out = _block_matmul(ap, bp, interpret=(mode == "interpret"),
                        out_dtype=out_dtype or a.dtype)
    return out[:m, :n]


def int8_matmul(xq: jnp.ndarray, wq: jnp.ndarray, x_scale, w_scale) -> jnp.ndarray:
    """QuantGr INT8 datapath."""
    mode = _mode()
    if mode == "ref":
        return ref.int8_matmul_ref(xq, wq, x_scale, w_scale)
    m, k = xq.shape
    _, n = wq.shape
    xp, wp = _pad2(xq, 128, 128), _pad2(wq, 128, 128)
    sp = jnp.pad(jnp.asarray(w_scale), (0, (-n) % 128))
    out = _int8_kernel(xp, wp, x_scale, sp, interpret=(mode == "interpret"))
    return out[:m, :n]


def bitmap_spmm_mode() -> str:
    """Which execution form a GraSp dispatch takes right now: "pallas"
    (real skip grid), "interpret" (same grid, interpreter), or "ref" (plain
    XLA gather+einsum over the compacted form — the silent dense fallback
    GraphServe surfaces as `backend_fallbacks`, DESIGN.md §10)."""
    return _mode()


def bitmap_spmm(block_sparse, h: jnp.ndarray) -> jnp.ndarray:
    """GraSp block-sparse aggregation; `block_sparse` from `to_block_sparse`
    / `compact_block_sparse` (a registered pytree, so its leaves may be
    runtime tracers — serving plans pass the structure as a vmapped plan
    argument). The ref path computes on the compacted form with plain XLA
    ops (no skip grid, padded entries multiplied not skipped): same math,
    none of the win — observable via `bitmap_spmm_mode()`."""
    mode = _mode()
    bs = block_sparse.block_size
    n_out = block_sparse.shape[0]
    n, f = h.shape
    hp = _pad2(h, bs, 128)
    blocks = jnp.asarray(block_sparse.blocks)
    cols = jnp.asarray(block_sparse.block_cols)
    counts = jnp.asarray(block_sparse.counts)
    if mode == "ref":
        out = ref.bitmap_spmm_block_ref(blocks, cols, counts, hp,
                                        block_size=bs)
    else:
        out = _bitmap_spmm_kernel(blocks, cols, counts, hp, block_size=bs,
                                  interpret=(mode == "interpret"))
    return out[:n_out, :f]


def bitmap_spmm_batched(block_sparse, h: jnp.ndarray) -> jnp.ndarray:
    """Batched GraSp aggregation: `block_sparse` is a stacked structure
    (`stack_block_sparse`, every leaf carrying a leading B) and h is
    (B, N, F). One vmap over the single-graph entry — the same lowering a
    batched ExecutionPlan produces when the operands carry a block
    structure, exposed here for direct callers and benchmarks."""
    return jax.vmap(bitmap_spmm, in_axes=(0, 0))(block_sparse, h)


def gat_attention(h: jnp.ndarray, alpha_dst: jnp.ndarray, alpha_src: jnp.ndarray,
                  bias_add: jnp.ndarray) -> jnp.ndarray:
    """Fused EffOp+GrAx1+GrAx2 GAT layer."""
    mode = _mode()
    if mode == "ref":
        return ref.gat_attention_ref(h, alpha_dst, alpha_src, bias_add)
    n, heads, f = h.shape
    fpad = (-f) % 128
    hp = jnp.pad(h, ((0, 0), (0, 0), (0, fpad))) if fpad else h
    out = _gat_kernel(hp, alpha_dst, alpha_src, bias_add,
                      interpret=(mode == "interpret"))
    return out[:, :, :f]


def sage_max(mask01: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """GrAx3 masked max aggregation."""
    mode = _mode()
    if mode == "ref":
        return ref.sage_max_ref(mask01, h)
    n, f = h.shape
    hp = _pad2(h, 128, 128)
    out = _sage_max_kernel(mask01, hp, interpret=(mode == "interpret"))
    return out[:n, :f]


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    q_offset: int = 0) -> jnp.ndarray:
    """GQA attention: Pallas flash kernel on TPU, exact oracle elsewhere.

    NOTE: the LM substrate's *dry-run* path does not call this for long
    sequences — it uses `repro.nn.attention.chunked_attention` (pure-JAX
    online softmax) so 32k/500k prefill compiles without O(S^2) buffers on
    any backend. This wrapper is the TPU hot-spot entry.
    """
    mode = _mode()
    if mode == "ref":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                       softcap=softcap, scale=scale,
                                       q_offset=q_offset)
    return _flash_kernel(q, k, v, causal=causal, window=window, softcap=softcap,
                         scale=scale, q_offset=q_offset,
                         interpret=(mode == "interpret"))
