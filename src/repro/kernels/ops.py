"""Public kernel entry points with backend routing.

Routing policy (documented in DESIGN.md §6), in PRECEDENCE ORDER — the first
rule that applies wins:

  1. REPRO_KERNEL_MODE=pallas|interpret|ref — explicit per-process override,
     checked BEFORE backend autodetect so tests and benchmarks can force a
     mode (e.g. exercise the real grid in interpret mode on a CPU box, or
     time the ref path on a TPU). Any other value raises.
  2. backend == "tpu"            -> real Pallas kernels (MXU tiling).
  3. REPRO_PALLAS_INTERPRET=1    -> Pallas kernels in interpret mode (CPU
                                    correctness validation; the CI legs).
  4. otherwise (CPU dry-run)     -> pure-jnp reference path. Same math, same
                                    FLOPs in cost_analysis, no TPU-only
                                    lowering — the multi-pod dry-run and the
                                    CPU serving engine compile this.

The environment is read at TRACE time: changing either variable after a
function has been jit-compiled does not re-route the cached executable.

Every wrapper pads operands to kernel tile multiples when needed and strips
the padding from the result (NodePad makes this a no-op for graph operands).
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .bitmap_spmm import bitmap_spmm as _bitmap_spmm_kernel
from .block_matmul import block_matmul as _block_matmul
from .flash_attention import flash_attention as _flash_kernel
from .fused_layers import fused_gat_full as _fused_gat_full_kernel
from .fused_layers import fused_gat_precombined as _fused_gat_pre_kernel
from .fused_layers import fused_gcn_dense as _fused_gcn_dense_kernel
from .fused_layers import fused_gcn_grasp as _fused_gcn_grasp_kernel
from .fused_layers import fused_gcn_int8 as _fused_gcn_int8_kernel
from .fused_layers import fused_sage as _fused_sage_kernel
from .gat_attention import gat_attention as _gat_kernel
from .int8_matmul import int8_matmul as _int8_kernel
from .sage_max import sage_max as _sage_max_kernel

_KERNEL_MODES = ("pallas", "interpret", "ref")


def _mode() -> str:
    forced = os.environ.get("REPRO_KERNEL_MODE", "")
    if forced:
        if forced not in _KERNEL_MODES:
            raise ValueError(
                f"REPRO_KERNEL_MODE={forced!r}: expected one of {_KERNEL_MODES}")
        return forced
    if jax.default_backend() == "tpu":
        return "pallas"
    if os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1":
        return "interpret"
    return "ref"


def _pad2(x: jnp.ndarray, m0: int, m1: int) -> jnp.ndarray:
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 == 0 and p1 == 0:
        return x
    return jnp.pad(x, ((0, p0), (0, p1)))


def matmul(a: jnp.ndarray, b: jnp.ndarray, *, out_dtype=None) -> jnp.ndarray:
    """StaGr aggregation backbone: C = A @ B (MXU-tiled on TPU)."""
    mode = _mode()
    if mode == "ref":
        return ref.matmul_ref(a, b, out_dtype=out_dtype)
    m, k = a.shape
    _, n = b.shape
    ap, bp = _pad2(a, 128, 128), _pad2(b, 128, 128)
    out = _block_matmul(ap, bp, interpret=(mode == "interpret"),
                        out_dtype=out_dtype or a.dtype)
    return out[:m, :n]


def int8_matmul(xq: jnp.ndarray, wq: jnp.ndarray, x_scale, w_scale) -> jnp.ndarray:
    """QuantGr INT8 datapath."""
    mode = _mode()
    if mode == "ref":
        return ref.int8_matmul_ref(xq, wq, x_scale, w_scale)
    m, k = xq.shape
    _, n = wq.shape
    xp, wp = _pad2(xq, 128, 128), _pad2(wq, 128, 128)
    sp = jnp.pad(jnp.asarray(w_scale), (0, (-n) % 128))
    out = _int8_kernel(xp, wp, x_scale, sp, interpret=(mode == "interpret"))
    return out[:m, :n]


def bitmap_spmm_mode() -> str:
    """Which execution form a GraSp dispatch takes right now: "pallas"
    (real skip grid), "interpret" (same grid, interpreter), or "ref" (plain
    XLA gather+einsum over the compacted form — the silent dense fallback
    GraphServe surfaces as `backend_fallbacks`, DESIGN.md §10)."""
    return _mode()


def bitmap_spmm(block_sparse, h: jnp.ndarray) -> jnp.ndarray:
    """GraSp block-sparse aggregation; `block_sparse` from `to_block_sparse`
    / `compact_block_sparse` (a registered pytree, so its leaves may be
    runtime tracers — serving plans pass the structure as a vmapped plan
    argument). The ref path computes on the compacted form with plain XLA
    ops (no skip grid, padded entries multiplied not skipped): same math,
    none of the win — observable via `bitmap_spmm_mode()`."""
    mode = _mode()
    bs = block_sparse.block_size
    n_out = block_sparse.shape[0]
    n, f = h.shape
    hp = _pad2(h, bs, 128)
    blocks = jnp.asarray(block_sparse.blocks)
    cols = jnp.asarray(block_sparse.block_cols)
    counts = jnp.asarray(block_sparse.counts)
    if mode == "ref":
        out = ref.bitmap_spmm_block_ref(blocks, cols, counts, hp,
                                        block_size=bs)
    else:
        out = _bitmap_spmm_kernel(blocks, cols, counts, hp, block_size=bs,
                                  interpret=(mode == "interpret"))
    return out[:n_out, :f]


def bitmap_spmm_batched(block_sparse, h: jnp.ndarray) -> jnp.ndarray:
    """Batched GraSp aggregation: `block_sparse` is a stacked structure
    (`stack_block_sparse`, every leaf carrying a leading B) and h is
    (B, N, F). One vmap over the single-graph entry — the same lowering a
    batched ExecutionPlan produces when the operands carry a block
    structure, exposed here for direct callers and benchmarks."""
    return jax.vmap(bitmap_spmm, in_axes=(0, 0))(block_sparse, h)


def gat_attention(h: jnp.ndarray, alpha_dst: jnp.ndarray, alpha_src: jnp.ndarray,
                  bias_add: jnp.ndarray) -> jnp.ndarray:
    """Fused EffOp+GrAx1+GrAx2 GAT layer."""
    mode = _mode()
    if mode == "ref":
        return ref.gat_attention_ref(h, alpha_dst, alpha_src, bias_add)
    n, heads, f = h.shape
    fpad = (-f) % 128
    hp = jnp.pad(h, ((0, 0), (0, 0), (0, fpad))) if fpad else h
    out = _gat_kernel(hp, alpha_dst, alpha_src, bias_add,
                      interpret=(mode == "interpret"))
    return out[:, :, :f]


def sage_max(mask01: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """GrAx3 masked max aggregation."""
    mode = _mode()
    if mode == "ref":
        return ref.sage_max_ref(mask01, h)
    n, f = h.shape
    hp = _pad2(h, 128, 128)
    out = _sage_max_kernel(mask01, hp, interpret=(mode == "interpret"))
    return out[:n, :f]


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    q_offset: int = 0) -> jnp.ndarray:
    """GQA attention: Pallas flash kernel on TPU, exact oracle elsewhere.

    NOTE: the LM substrate's *dry-run* path does not call this for long
    sequences — it uses `repro.nn.attention.chunked_attention` (pure-JAX
    online softmax) so 32k/500k prefill compiles without O(S^2) buffers on
    any backend. This wrapper is the TPU hot-spot entry.
    """
    mode = _mode()
    if mode == "ref":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                       softcap=softcap, scale=scale,
                                       q_offset=q_offset)
    return _flash_kernel(q, k, v, causal=causal, window=window, softcap=softcap,
                         scale=scale, q_offset=q_offset,
                         interpret=(mode == "interpret"))


# ----------------------------------------------------- fused layer entries
#
# One entry per GNN kind; the (tier x backend) variant is selected by which
# operands are present — the same discriminators `core.layers` uses for the
# unfused path, so a fused plan traces the same structure per PlanKey.


def fused_gcn_layer(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, *,
                    norm_adj: Optional[jnp.ndarray] = None,
                    block_sparse=None, quant=None,
                    activation: str = "none") -> jnp.ndarray:
    """Fused GCN layer: act(aggregate(combine(X)) + b) in one kernel pass.

    Exactly one of `norm_adj` (dense Â), `block_sparse` (GraSp form) must be
    given, or `quant` = (wq, w_scale, x_scale, h_scale, aq, a_scale) for the
    QuantGr tier (dense int8 Â). b: (O,) or (1, O).
    """
    mode = _mode()
    b2 = jnp.reshape(b, (1, -1))
    n = x.shape[0]
    o = w.shape[1] if quant is None else quant[0].shape[1]
    if mode == "ref":
        if block_sparse is not None:
            return ref.fused_gcn_grasp_layer_ref(
                jnp.asarray(block_sparse.blocks),
                jnp.asarray(block_sparse.block_cols),
                jnp.asarray(block_sparse.counts),
                _pad2(x, block_sparse.block_size, 1), w, b2,
                block_size=block_sparse.block_size,
                activation=activation)[:n]
        return ref.fused_gcn_layer_ref(x, w, b2, norm_adj=norm_adj,
                                       quant=quant, activation=activation)
    interp = mode == "interpret"
    if block_sparse is not None:
        bs = block_sparse.block_size
        out = _fused_gcn_grasp_kernel(
            jnp.asarray(block_sparse.blocks),
            jnp.asarray(block_sparse.block_cols),
            jnp.asarray(block_sparse.counts),
            _pad2(x, bs, 128), _pad2(w, 128, 128),
            _pad2(b2, 1, 128), block_size=bs, activation=activation,
            interpret=interp)
        return out[:n, :o]
    if quant is not None:
        wq, w_scale, x_scale, h_scale, aq, a_scale = quant
        sw = jnp.reshape(x_scale * w_scale, (1, -1))
        out = _fused_gcn_int8_kernel(
            _pad2(x, 128, 128), _pad2(wq, 128, 128), _pad2(sw, 1, 128),
            jnp.reshape(x_scale, (1, 1)), jnp.reshape(h_scale, (1, 1)),
            _pad2(aq, 128, 128), _pad2(jnp.reshape(a_scale, (-1, 1)), 128, 1),
            _pad2(b2, 1, 128), activation=activation, interpret=interp)
        return out[:n, :o]
    out = _fused_gcn_dense_kernel(
        _pad2(norm_adj, 128, 128), _pad2(x, 128, 128), _pad2(w, 128, 128),
        _pad2(b2, 1, 128), activation=activation, interpret=interp)
    return out[:n, :o]


def fused_gat_layer(x: Optional[jnp.ndarray], w: Optional[jnp.ndarray],
                    a_src: jnp.ndarray, a_dst: jnp.ndarray,
                    bias_add: jnp.ndarray, b: jnp.ndarray, *,
                    activation: str = "none",
                    precombined=None) -> jnp.ndarray:
    """Fused GAT layer -> (N, H, F).

    x: (N, Fin); w: (Fin, H, F); a_src/a_dst: (H, F); bias_add: (N, N);
    b: (H, F). `precombined` = (h, alpha_dst, alpha_src) for QuantGr tiers:
    the int8 combine runs outside, attention + epilogue stay fused.
    """
    mode = _mode()
    if mode == "ref":
        return ref.fused_gat_layer_ref(x, w, a_src, a_dst, bias_add, b,
                                       activation=activation,
                                       precombined=precombined)
    interp = mode == "interpret"
    n = bias_add.shape[0]
    f = b.shape[1]
    npad = (-n) % 128
    # Padded bias rows/cols are fully masked (-1e9): padded columns never
    # win the row softmax, padded rows produce garbage that is stripped.
    bias_p = jnp.pad(bias_add, ((0, npad), (0, npad)),
                     constant_values=ref.NEG_INF)
    bp = _pad2(b, 1, 128)
    if precombined is not None:
        h, alpha_dst, alpha_src = precombined
        out = _fused_gat_pre_kernel(
            jnp.pad(h, ((0, npad), (0, 0), (0, (-f) % 128))),
            _pad2(alpha_dst, 128, 1), _pad2(alpha_src, 128, 1), bias_p, bp,
            activation=activation, interpret=interp)
        return out[:n, :, :f]
    out = _fused_gat_full_kernel(
        _pad2(x, 128, 128),
        jnp.pad(w, ((0, (-w.shape[0]) % 128), (0, 0), (0, (-f) % 128))),
        _pad2(a_src, 1, 128), _pad2(a_dst, 1, 128), bias_p, bp,
        activation=activation, interpret=interp)
    return out[:n, :, :f]


def fused_sage_layer(x: jnp.ndarray, w_self: jnp.ndarray,
                     w_neigh: jnp.ndarray, b: jnp.ndarray, *,
                     mean_mask: Optional[jnp.ndarray] = None,
                     sample_mask: Optional[jnp.ndarray] = None,
                     pooled: Optional[jnp.ndarray] = None,
                     activation: str = "none") -> jnp.ndarray:
    """Fused SAGE layer: act(X @ Wself + AGG @ Wneigh + b).

    mean aggregation: pass `mean_mask`; GrAx3 max aggregation: pass the 0/1
    `sample_mask` plus the non-negative `pooled` features. b: (O,) or (1, O).
    """
    mode = _mode()
    b2 = jnp.reshape(b, (1, -1))
    aggregator = "mean" if mean_mask is not None else "max"
    mask = mean_mask if mean_mask is not None else sample_mask
    xk = x if mean_mask is not None else pooled
    if mode == "ref":
        return ref.fused_sage_layer_ref(mask, xk, x, w_self, w_neigh, b2,
                                        aggregator=aggregator,
                                        activation=activation)
    n = x.shape[0]
    o = w_self.shape[1]
    out = _fused_sage_kernel(
        _pad2(mask, 128, 128), _pad2(xk, 128, 128), _pad2(x, 128, 128),
        _pad2(w_self, 128, 128), _pad2(w_neigh, 128, 128), _pad2(b2, 1, 128),
        aggregator=aggregator, activation=activation,
        interpret=(mode == "interpret"))
    return out[:n, :o]
