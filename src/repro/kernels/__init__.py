"""Pallas TPU kernels for the compute hot-spots the paper optimizes.

<name>.py = pl.pallas_call + BlockSpec; ops.py = jit'd wrappers with backend
routing; ref.py = pure-jnp oracles the tests assert_allclose against.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
