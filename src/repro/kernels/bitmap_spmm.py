"""GraSp block-sparse SpMM: Â @ H skipping zero 128x128 blocks.

The TPU-native realization of the paper's sparsity bitmap (Fig. 13): the host
compacts Â's non-zero blocks (`repro.core.sparsity.to_block_sparse`) and this
kernel visits ONLY those. The block-column indices live in SMEM via scalar
prefetch and drive the *index maps* — the same mechanism the NPU's bitmap
uses to steer its DMA engine: data-dependent block fetch, zero wasted MACs.

Grid: (row_blocks, F/bf, max_nnz). The k axis walks each block-row's
compacted non-zero list; rows with fewer blocks mask the tail via pl.when
(counts in SMEM), so padded entries cost a skipped grid step, never a matmul.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BF = 128


def _spmm_kernel(counts_ref, cols_ref, blocks_ref, h_ref, o_ref, acc_ref, *,
                 max_nnz: int):
    i = pl.program_id(0)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Skip padded tail entries: only counts_ref[i] blocks are real.
    @pl.when(k < counts_ref[i])
    def _mac():
        acc_ref[...] += jnp.dot(blocks_ref[0], h_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(k == max_nnz - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_size", "bf", "interpret"))
def bitmap_spmm(blocks: jnp.ndarray, block_cols: jnp.ndarray,
                counts: jnp.ndarray, h: jnp.ndarray, *, block_size: int = 128,
                bf: int = DEFAULT_BF, interpret: bool = False) -> jnp.ndarray:
    """out = Â @ h from the compacted block form.

    blocks:     (rb * max_nnz, bs, bs) gathered non-zero blocks.
    block_cols: (rb, max_nnz) int32 column-block index per entry.
    counts:     (rb,) int32 number of real entries per block-row.
    h:          (N, F) dense right-hand side; N = cb * bs, F % bf == 0.
    """
    bs = block_size
    rb, max_nnz = block_cols.shape
    n, f = h.shape
    assert blocks.shape == (rb * max_nnz, bs, bs), (blocks.shape, rb, max_nnz)
    assert n % bs == 0 and f % bf == 0, (h.shape, bs, bf)

    grid = (rb, f // bf, max_nnz)
    kernel = functools.partial(_spmm_kernel, max_nnz=max_nnz)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,  # counts, block_cols -> SMEM, feed index maps
            grid=grid,
            in_specs=[
                # compacted block list: entry (i * max_nnz + k)
                pl.BlockSpec((1, bs, bs),
                             lambda i, j, k, counts, cols: (i * max_nnz + k, 0, 0)),
                # H row-block chosen BY THE BITMAP: cols[i, k] — the
                # data-dependent fetch that skips zero blocks entirely.
                pl.BlockSpec((bs, bf),
                             lambda i, j, k, counts, cols: (cols[i, k], j)),
            ],
            out_specs=pl.BlockSpec((bs, bf),
                                   lambda i, j, k, counts, cols: (i, j)),
            scratch_shapes=[pltpu.VMEM((bs, bf), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((rb * bs, f), h.dtype),
        interpret=interpret,
    )(counts, block_cols, blocks, h)
