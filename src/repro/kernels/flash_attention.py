"""Block-streaming (flash) GQA attention for the LM substrate.

TPU-target kernel for the attention hot-spot of the assigned LM archs:
online-softmax over KV blocks, causal and/or sliding-window masking computed
from block indices (never materializing an (Sq, Skv) mask), optional gemma2
tanh logit soft-capping. GQA is expressed through the index maps: query head
hd reads KV head hd // group — no jnp.repeat materialization.

Grid: (B, H, Sq/bq, Skv/bk), KV innermost so the (bq, d) accumulator and the
(bq, 1) running max/denominator live in VMEM across the KV sweep. Fully
masked blocks (beyond causal frontier / outside the window) are skipped with
pl.when — the same "skip what the mask says is zero" move as GraSp, applied
to the attention schedule.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e9


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  softcap: Optional[float], bq: int, bk: int, kv_steps: int,
                  q_offset: int):
    iq = pl.program_id(2)
    jk = pl.program_id(3)

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * bq + q_offset          # absolute positions of this q block
    k_start = jk * bk

    # Block-level skip: entirely above the causal diagonal, or entirely
    # outside the sliding window -> no compute at all for this block.
    needed = True
    if causal:
        needed = jnp.asarray(k_start <= q_start + bq - 1)
    else:
        needed = jnp.asarray(True)
    if window is not None:
        needed = jnp.logical_and(
            needed, k_start + bk - 1 > q_start - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[...][0, :, 0, :]                    # (bq, d)
        k = k_ref[...][0, :, 0, :]                    # (bk, d)
        v = v_ref[...][0, :, 0, :]                    # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                            # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                         # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                # rescale factor
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(jk == kv_steps - 1)
    def _store():
        denom = jnp.maximum(l_ref[...], 1e-12)
        o_ref[...] = (acc_ref[...] / denom).astype(o_ref.dtype)[None, :, None, :]


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "bq", "bk", "q_offset", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None, q_offset: int = 0,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool = False) -> jnp.ndarray:
    """q: (B, Sq, H, D); k, v: (B, Skv, KV, D); H % KV == 0 -> (B, Sq, H, D)."""
    b, sq, h, d = q.shape
    _, skv, kv, _ = k.shape
    assert h % kv == 0, (h, kv)
    group = h // kv
    bq_, bk_ = min(bq, sq), min(bk, skv)
    assert sq % bq_ == 0 and skv % bk_ == 0, (sq, skv, bq_, bk_)
    scale_ = scale if scale is not None else d ** -0.5
    kv_steps = skv // bk_
    grid = (b, h, sq // bq_, kv_steps)
    kernel = functools.partial(
        _flash_kernel, scale=scale_, causal=causal, window=window,
        softcap=softcap, bq=bq_, bk=bk_, kv_steps=kv_steps, q_offset=q_offset)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq_, 1, d), lambda bb, hd, iq, jk: (bb, iq, hd, 0)),
            pl.BlockSpec((1, bk_, 1, d),
                         lambda bb, hd, iq, jk: (bb, jk, hd // group, 0)),
            pl.BlockSpec((1, bk_, 1, d),
                         lambda bb, hd, iq, jk: (bb, jk, hd // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq_, 1, d), lambda bb, hd, iq, jk: (bb, iq, hd, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_, d), jnp.float32),
            pltpu.VMEM((bq_, 1), jnp.float32),
            pltpu.VMEM((bq_, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
