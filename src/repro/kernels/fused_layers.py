"""Fused per-layer GNN kernels: aggregate + combine (+ bias + act), ONE grid.

GraNNite's Step-2 claim is that the win comes from keeping the whole layer on
the data-parallel engine: EffOp rewrites the per-request control flow as
masked arithmetic and the GrAx variants fold attention / broadcast-add / max
into the same pass. These kernels are the TPU-native form of that claim — a
single `pl.pallas_call` per layer whose grid produces the combine result
H = X @ W *into VMEM scratch* and consumes it from there for aggregation,
bias and activation, so the (N, hidden) intermediate never round-trips to HBM
(the paper's DSP<->DRAM traffic, our HBM bytes in `benchmarks/tpu_model.py`).

One kernel per (kind x tier x backend) hot combination:

  * `fused_gcn_dense`  — act(Â @ (X @ W) + b), fp32. Grid (O/bn, N/bm, N/bk);
    at i == 0 each k-step writes one row-block of the H strip into VMEM
    (zero extra FLOPs: the strip is computed exactly once per output strip),
    every step MACs Â's row-block against the resident strip.
  * `fused_gcn_int8`   — the QuantGr tier: the combine phase quantizes X,
    runs the s8xs8->s32 MXU dot (the `int8_matmul` epilogue), re-quantizes H
    to int8 in VMEM, and the aggregate phase is Âq @ Hq with the per-row
    dequant + bias + act folded into the store. Bit-identical to the unfused
    `apply_quantized_linear` + `apply_quantized_agg` chain.
  * `fused_gcn_grasp`  — the GraSp backend: same combine phase, then the
    block-skip walk of `bitmap_spmm` (scalar-prefetched block-column bitmap
    steering VMEM reads) against the resident H strip.
  * `fused_gat_full`   — combine + GrAx2 broadcast-add + GrAx1 additive mask
    + row softmax + attn@H + bias + act per head, one grid. The alpha terms
    are reduced from the VMEM H blocks as they are produced.
  * `fused_gat_precombined` — QuantGr GAT: H comes from the int8 combine
    outside; attention + bias + act stay fused (the `gat_attention` grid with
    the epilogue folded in).
  * `fused_sage`       — mean (M @ X) or GrAx3 masked-max aggregation
    accumulated in VMEM, with BOTH combines (self + neigh) and bias + act in
    the store step.

Activation is a *static* kernel parameter ("none" | "relu" | "elu") — EffOp
dispatch means the per-layer control flow is resolved at trace time into the
epilogue arithmetic, never into per-request host branching.

All shapes must divide the 128 tiles; `ops.py` wrappers pad and strip
(NodePad makes that a no-op for serving operands).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = (128, 128, 128)  # (bm, bn, bk)
_INT8_MAX = 127.0
_ROW_SLAB = 8                    # GrAx3 slab rows: 8*128*Fin*4B stays < VMEM


def _act(z: jnp.ndarray, activation: str) -> jnp.ndarray:
    if activation == "relu":
        return jnp.maximum(z, 0.0)
    if activation == "elu":
        return jnp.where(z > 0, z, jnp.expm1(z))
    if activation == "none":
        return z
    raise ValueError(f"unknown activation {activation!r}")


# ------------------------------------------------------------- GCN (dense)


def _gcn_dense_kernel(a_ref, x_ref, w_ref, b_ref, o_ref, hbuf_ref, acc_ref, *,
                      k_steps: int, bk: int, activation: str):
    i = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Combine phase: the H = X @ W[:, strip] row-block is produced straight
    # into VMEM, once per output strip (i == 0), never written to HBM.
    @pl.when(i == 0)
    def _combine():
        hbuf_ref[pl.ds(k * bk, bk), :] = jnp.dot(
            x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    # Aggregate phase: Â row-block x the VMEM-resident H strip.
    acc_ref[...] += jnp.dot(a_ref[...], hbuf_ref[pl.ds(k * bk, bk), :],
                            preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _store():
        o_ref[...] = _act(acc_ref[...] + b_ref[...],
                          activation).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "activation", "interpret"))
def fused_gcn_dense(norm_adj: jnp.ndarray, x: jnp.ndarray, w: jnp.ndarray,
                    b: jnp.ndarray, *, block: tuple = DEFAULT_BLOCK,
                    activation: str = "none",
                    interpret: bool = False) -> jnp.ndarray:
    """out = act(Â @ (X @ W) + b).

    norm_adj: (N, N); x: (N, Fin); w: (Fin, O); b: (1, O).
    N and O must divide the 128 tiles (callers pad via `ops.fused_gcn_layer`).
    """
    n, fin = x.shape
    _, o = w.shape
    assert norm_adj.shape == (n, n) and b.shape == (1, o)
    bm, bn, bk = block
    bm, bn, bk = min(bm, n), min(bn, o), min(bk, n)
    assert n % bm == 0 and n % bk == 0 and o % bn == 0, (x.shape, w.shape)
    k_steps = n // bk
    return pl.pallas_call(
        functools.partial(_gcn_dense_kernel, k_steps=k_steps, bk=bk,
                          activation=activation),
        grid=(o // bn, n // bm, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda j, i, k: (i, k)),     # Â
            pl.BlockSpec((bk, fin), lambda j, i, k: (k, 0)),    # X
            pl.BlockSpec((fin, bn), lambda j, i, k: (0, j)),    # W strip
            pl.BlockSpec((1, bn), lambda j, i, k: (0, j)),      # bias strip
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda j, i, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, o), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, bn), jnp.float32),
                        pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(norm_adj, x, w, b)


# -------------------------------------------------------- GCN (QuantGr int8)


def _gcn_int8_kernel(x_ref, wq_ref, sw_ref, sx_ref, sh_ref, aq_ref, asc_ref,
                     b_ref, o_ref, hqbuf_ref, acc_ref, *, k_steps: int,
                     bk: int, activation: str):
    i = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Combine phase (QuantGr): quantize X, s8xs8->s32 dot, dequant by the
    # folded x_scale*w_scale strip, re-quantize H to int8 — all in VMEM.
    @pl.when(i == 0)
    def _combine():
        xq = jnp.clip(jnp.round(x_ref[...] / sx_ref[0, 0]),
                      -_INT8_MAX, _INT8_MAX).astype(jnp.int8)
        hf = jax.lax.dot_general(
            xq, wq_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32).astype(jnp.float32) * sw_ref[...]
        hqbuf_ref[pl.ds(k * bk, bk), :] = jnp.clip(
            jnp.round(hf / sh_ref[0, 0]), -_INT8_MAX, _INT8_MAX
        ).astype(jnp.int8)

    # Aggregate phase: Âq @ Hq in int32.
    acc_ref[...] += jax.lax.dot_general(
        aq_ref[...], hqbuf_ref[pl.ds(k * bk, bk), :],
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    @pl.when(k == k_steps - 1)
    def _store():
        z = acc_ref[...].astype(jnp.float32) * (asc_ref[...] * sh_ref[0, 0]) \
            + b_ref[...]
        o_ref[...] = _act(z, activation).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "activation", "interpret"))
def fused_gcn_int8(x: jnp.ndarray, wq: jnp.ndarray, sw: jnp.ndarray,
                   x_scale: jnp.ndarray, h_scale: jnp.ndarray,
                   aq: jnp.ndarray, a_scale: jnp.ndarray, b: jnp.ndarray, *,
                   block: tuple = DEFAULT_BLOCK, activation: str = "none",
                   interpret: bool = False) -> jnp.ndarray:
    """QuantGr fused layer, bit-identical to the unfused int8 chain.

    x: (N, Fin) fp32; wq: (Fin, O) int8; sw: (1, O) = x_scale * w_scale;
    x_scale, h_scale: (1, 1); aq: (N, N) int8; a_scale: (N, 1); b: (1, O).
    """
    n, fin = x.shape
    _, o = wq.shape
    assert aq.shape == (n, n) and a_scale.shape == (n, 1)
    bm, bn, bk = block
    bm, bn, bk = min(bm, n), min(bn, o), min(bk, n)
    assert n % bm == 0 and n % bk == 0 and o % bn == 0, (x.shape, wq.shape)
    k_steps = n // bk
    return pl.pallas_call(
        functools.partial(_gcn_int8_kernel, k_steps=k_steps, bk=bk,
                          activation=activation),
        grid=(o // bn, n // bm, k_steps),
        in_specs=[
            pl.BlockSpec((bk, fin), lambda j, i, k: (k, 0)),    # X
            pl.BlockSpec((fin, bn), lambda j, i, k: (0, j)),    # Wq strip
            pl.BlockSpec((1, bn), lambda j, i, k: (0, j)),      # sw strip
            pl.BlockSpec((1, 1), lambda j, i, k: (0, 0)),       # x_scale
            pl.BlockSpec((1, 1), lambda j, i, k: (0, 0)),       # h_scale
            pl.BlockSpec((bm, bk), lambda j, i, k: (i, k)),     # Âq
            pl.BlockSpec((bm, 1), lambda j, i, k: (i, 0)),      # a_scale rows
            pl.BlockSpec((1, bn), lambda j, i, k: (0, j)),      # bias strip
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda j, i, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, o), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, bn), jnp.int8),
                        pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x, wq, sw, x_scale, h_scale, aq, a_scale, b)


# -------------------------------------------------------- GCN (GraSp blocks)


def _gcn_grasp_kernel(counts_ref, cols_ref, x_ref, w_ref, blocks_ref, b_ref,
                      o_ref, hbuf_ref, acc_ref, *, cb: int, max_nnz: int,
                      bs: int, activation: str):
    i = pl.program_id(1)
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Combine phase: the first cb steps of each output strip build the full
    # H strip in VMEM (i == 0 only — it is shared by every block-row).
    @pl.when((i == 0) & (t < cb))
    def _combine():
        hbuf_ref[pl.ds(t * bs, bs), :] = jnp.dot(
            x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    # Skip walk: the remaining max_nnz steps visit ONLY the bitmap's blocks;
    # the block-column index steers a VMEM read instead of an HBM fetch.
    @pl.when((t >= cb) & (t - cb < counts_ref[i]))
    def _mac():
        col = cols_ref[i, jnp.clip(t - cb, 0, max_nnz - 1)]
        acc_ref[...] += jnp.dot(blocks_ref[0],
                                hbuf_ref[pl.ds(col * bs, bs), :],
                                preferred_element_type=jnp.float32)

    @pl.when(t == cb + max_nnz - 1)
    def _store():
        o_ref[...] = _act(acc_ref[...] + b_ref[...],
                          activation).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_size", "bn", "activation",
                                             "interpret"))
def fused_gcn_grasp(blocks: jnp.ndarray, block_cols: jnp.ndarray,
                    counts: jnp.ndarray, x: jnp.ndarray, w: jnp.ndarray,
                    b: jnp.ndarray, *, block_size: int = 128, bn: int = 128,
                    activation: str = "none",
                    interpret: bool = False) -> jnp.ndarray:
    """GraSp fused layer: combine + block-skip aggregate + bias + act.

    blocks/block_cols/counts: the compacted form of `bitmap_spmm`;
    x: (N, Fin) with N = rb * bs; w: (Fin, O); b: (1, O).
    """
    bs = block_size
    rb, max_nnz = block_cols.shape
    n, fin = x.shape
    _, o = w.shape
    assert blocks.shape == (rb * max_nnz, bs, bs), (blocks.shape, rb, max_nnz)
    assert n == rb * bs and o % bn == 0, (x.shape, w.shape, bs)
    cb = n // bs
    kernel = functools.partial(_gcn_grasp_kernel, cb=cb, max_nnz=max_nnz,
                               bs=bs, activation=activation)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,  # counts, block_cols -> SMEM
            grid=(o // bn, rb, cb + max_nnz),
            in_specs=[
                # X block: walks rows during the combine phase, parks on the
                # last block during the skip walk (clamped index).
                pl.BlockSpec((bs, fin),
                             lambda j, i, t, counts, cols:
                             (jnp.minimum(t, cb - 1), 0)),
                pl.BlockSpec((fin, bn), lambda j, i, t, counts, cols: (0, j)),
                # Compacted block list entry (i * max_nnz + (t - cb)).
                pl.BlockSpec((1, bs, bs),
                             lambda j, i, t, counts, cols:
                             (i * max_nnz + jnp.clip(t - cb, 0, max_nnz - 1),
                              0, 0)),
                pl.BlockSpec((1, bn), lambda j, i, t, counts, cols: (0, j)),
            ],
            out_specs=pl.BlockSpec((bs, bn),
                                   lambda j, i, t, counts, cols: (i, j)),
            scratch_shapes=[pltpu.VMEM((n, bn), jnp.float32),
                            pltpu.VMEM((bs, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((n, o), x.dtype),
        interpret=interpret,
    )(counts, block_cols, x, w, blocks, b)


# --------------------------------------------------------------- GAT (full)


def _gat_full_kernel(x_ref, w_ref, asv_ref, adv_ref, bias_ref, b_ref, o_ref,
                     hbuf_ref, asb_ref, adb_ref, *, k_steps: int, bm: int,
                     bk: int, negative_slope: float, activation: str):
    i = pl.program_id(1)
    k = pl.program_id(2)

    # Combine phase: produce this head's H blocks into VMEM and reduce the
    # alpha terms from them as they appear (GrAx2's operands).
    @pl.when(i == 0)
    def _combine():
        hblk = jnp.dot(x_ref[...], w_ref[...][:, 0, :],
                       preferred_element_type=jnp.float32)      # (bk, F)
        hbuf_ref[pl.ds(k * bk, bk), :] = hblk
        asb_ref[pl.ds(k * bk, bk), :] = jnp.sum(
            hblk * asv_ref[...], axis=1, keepdims=True)
        adb_ref[pl.ds(k * bk, bk), :] = jnp.sum(
            hblk * adv_ref[...], axis=1, keepdims=True)

    # Attention phase: GrAx2 broadcast-add, leaky, GrAx1 additive mask, row
    # softmax, attn @ H, bias + act — the (bm, N) score strip never leaves
    # VMEM.
    @pl.when(k == k_steps - 1)
    def _attend():
        ad = adb_ref[pl.ds(i * bm, bm), :]                      # (bm, 1)
        e = ad + asb_ref[...][:, 0][None, :]                    # GrAx2
        e = jnp.where(e >= 0, e, negative_slope * e)
        e = e + bias_ref[...]                                   # GrAx1
        e = e - jnp.max(e, axis=1, keepdims=True)
        p = jnp.exp(e)
        attn = p / jnp.maximum(p.sum(axis=1, keepdims=True), 1e-12)
        z = jnp.dot(attn, hbuf_ref[...],
                    preferred_element_type=jnp.float32) + b_ref[...]
        o_ref[...] = _act(z, activation).astype(o_ref.dtype)[:, None, :]


@functools.partial(jax.jit, static_argnames=("block", "negative_slope",
                                             "activation", "interpret"))
def fused_gat_full(x: jnp.ndarray, w: jnp.ndarray, a_src: jnp.ndarray,
                   a_dst: jnp.ndarray, bias_add: jnp.ndarray, b: jnp.ndarray,
                   *, block: tuple = DEFAULT_BLOCK,
                   negative_slope: float = 0.2, activation: str = "none",
                   interpret: bool = False) -> jnp.ndarray:
    """Whole fp32 GAT layer in one grid, per head.

    x: (N, Fin); w: (Fin, H, F); a_src/a_dst: (H, F); bias_add: (N, N);
    b: (H, F) per-head bias rows -> out (N, H, F).
    """
    n, fin = x.shape
    _, heads, f = w.shape
    assert a_src.shape == (heads, f) and bias_add.shape == (n, n)
    assert b.shape == (heads, f)
    bm, _, bk = block
    bm, bk = min(bm, n), min(bk, n)
    assert n % bm == 0 and n % bk == 0, (n, block)
    k_steps = n // bk
    return pl.pallas_call(
        functools.partial(_gat_full_kernel, k_steps=k_steps, bm=bm, bk=bk,
                          negative_slope=negative_slope, activation=activation),
        grid=(heads, n // bm, k_steps),
        in_specs=[
            pl.BlockSpec((bk, fin), lambda hd, i, k: (k, 0)),      # X
            pl.BlockSpec((fin, 1, f), lambda hd, i, k: (0, hd, 0)),  # W head
            pl.BlockSpec((1, f), lambda hd, i, k: (hd, 0)),        # a_src
            pl.BlockSpec((1, f), lambda hd, i, k: (hd, 0)),        # a_dst
            pl.BlockSpec((bm, n), lambda hd, i, k: (i, 0)),        # bias strip
            pl.BlockSpec((1, f), lambda hd, i, k: (hd, 0)),        # b head
        ],
        out_specs=pl.BlockSpec((bm, 1, f), lambda hd, i, k: (i, hd, 0)),
        out_shape=jax.ShapeDtypeStruct((n, heads, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, f), jnp.float32),
                        pltpu.VMEM((n, 1), jnp.float32),
                        pltpu.VMEM((n, 1), jnp.float32)],
        interpret=interpret,
    )(x, w, a_src, a_dst, bias_add, b)


# -------------------------------------------------- GAT (precombined tiers)


def _gat_pre_kernel(ad_ref, as_ref, bias_ref, h_ref, b_ref, o_ref, *,
                    negative_slope: float, activation: str):
    ad = ad_ref[...]                      # (bm, 1)
    a_src = as_ref[...][:, 0]             # (N,)
    e = ad + a_src[None, :]               # GrAx2
    e = jnp.where(e >= 0, e, negative_slope * e)
    e = e + bias_ref[...]                 # GrAx1
    e = e - jnp.max(e, axis=1, keepdims=True)
    p = jnp.exp(e)
    attn = p / jnp.maximum(p.sum(axis=1, keepdims=True), 1e-12)
    h = h_ref[...][:, 0, :]               # (N, F)
    z = jnp.dot(attn.astype(h.dtype), h,
                preferred_element_type=jnp.float32) + b_ref[...]
    o_ref[...] = _act(z, activation).astype(o_ref.dtype)[:, None, :]


@functools.partial(jax.jit, static_argnames=("bm", "negative_slope",
                                             "activation", "interpret"))
def fused_gat_precombined(h: jnp.ndarray, alpha_dst: jnp.ndarray,
                          alpha_src: jnp.ndarray, bias_add: jnp.ndarray,
                          b: jnp.ndarray, *, bm: int = 128,
                          negative_slope: float = 0.2,
                          activation: str = "none",
                          interpret: bool = False) -> jnp.ndarray:
    """QuantGr GAT: H from the int8 combine outside; attention + bias + act
    fused. h: (N, H, F); alpha_*: (N, H); bias_add: (N, N); b: (H, F)."""
    n, heads, f = h.shape
    assert alpha_dst.shape == (n, heads) and bias_add.shape == (n, n)
    assert b.shape == (heads, f)
    bm = min(bm, n)
    assert n % bm == 0, (n, bm)
    return pl.pallas_call(
        functools.partial(_gat_pre_kernel, negative_slope=negative_slope,
                          activation=activation),
        grid=(heads, n // bm),
        in_specs=[
            pl.BlockSpec((bm, 1), lambda hd, i: (i, hd)),       # alpha_dst
            pl.BlockSpec((n, 1), lambda hd, i: (0, hd)),        # alpha_src
            pl.BlockSpec((bm, n), lambda hd, i: (i, 0)),        # bias strip
            pl.BlockSpec((n, 1, f), lambda hd, i: (0, hd, 0)),  # h, this head
            pl.BlockSpec((1, f), lambda hd, i: (hd, 0)),        # b head
        ],
        out_specs=pl.BlockSpec((bm, 1, f), lambda hd, i: (i, hd, 0)),
        out_shape=jax.ShapeDtypeStruct((n, heads, f), h.dtype),
        interpret=interpret,
    )(alpha_dst, alpha_src, bias_add, h, b)


# -------------------------------------------------------------------- SAGE


def _sage_kernel(mm_ref, xk_ref, xs_ref, ws_ref, wn_ref, b_ref, o_ref,
                 aggbuf_ref, *, k_steps: int, aggregator: str, slab: int,
                 n_slabs: int, activation: str):
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when((j == 0) & (k == 0))
    def _init():
        aggbuf_ref[...] = jnp.zeros_like(aggbuf_ref)

    # Aggregate phase (j == 0 only: the buffer is shared by every output
    # strip of this row-block): mean is M @ X on the MXU; max is the GrAx3
    # masked multiply + max-pool streamed in row slabs.
    @pl.when(j == 0)
    def _agg():
        if aggregator == "mean":
            aggbuf_ref[...] += jnp.dot(mm_ref[...], xk_ref[...],
                                       preferred_element_type=jnp.float32)
        else:
            def body(r, _):
                sl = pl.ds(r * slab, slab)
                msk = mm_ref[:, sl]                       # (bm, slab)
                pkk = xk_ref[sl, :]                       # (slab, Fin)
                prod = msk[:, :, None] * pkk[None, :, :]  # GrAx3
                aggbuf_ref[...] = jnp.maximum(aggbuf_ref[...],
                                              jnp.max(prod, axis=1))
                return 0

            jax.lax.fori_loop(0, n_slabs, body, 0)

    # Store: both combines (self + neigh) + bias + act in one epilogue.
    @pl.when(k == k_steps - 1)
    def _store():
        z = (jnp.dot(xs_ref[...], ws_ref[...],
                     preferred_element_type=jnp.float32)
             + jnp.dot(aggbuf_ref[...], wn_ref[...],
                       preferred_element_type=jnp.float32)
             + b_ref[...])
        o_ref[...] = _act(z, activation).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("aggregator", "block",
                                             "activation", "interpret"))
def fused_sage(mask: jnp.ndarray, xk: jnp.ndarray, x: jnp.ndarray,
               w_self: jnp.ndarray, w_neigh: jnp.ndarray, b: jnp.ndarray, *,
               aggregator: str = "mean", block: tuple = DEFAULT_BLOCK,
               activation: str = "none", interpret: bool = False) -> jnp.ndarray:
    """out = act(X @ Wself + AGG(mask, xk) @ Wneigh + b).

    mask: (N, N) — mean_mask (mean) or 0/1 sample_mask (max);
    xk: (N, Fin) — X itself (mean) or the non-negative pooled features (max);
    x: (N, Fin); w_self/w_neigh: (Fin, O); b: (1, O).
    """
    n, fin = x.shape
    _, o = w_self.shape
    assert mask.shape == (n, n) and xk.shape == (n, fin)
    assert w_neigh.shape == (fin, o) and b.shape == (1, o)
    bm, bn, bk = DEFAULT_BLOCK if block is None else block
    bm, bn, bk = min(bm, n), min(bn, o), min(bk, n)
    assert n % bm == 0 and n % bk == 0 and o % bn == 0, (x.shape, w_self.shape)
    slab = min(bk, _ROW_SLAB)
    k_steps = n // bk
    return pl.pallas_call(
        functools.partial(_sage_kernel, k_steps=k_steps, aggregator=aggregator,
                          slab=slab, n_slabs=bk // slab, activation=activation),
        grid=(n // bm, o // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),     # mask
            pl.BlockSpec((bk, fin), lambda i, j, k: (k, 0)),    # xk
            pl.BlockSpec((bm, fin), lambda i, j, k: (i, 0)),    # X row strip
            pl.BlockSpec((fin, bn), lambda i, j, k: (0, j)),    # Wself strip
            pl.BlockSpec((fin, bn), lambda i, j, k: (0, j)),    # Wneigh strip
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),      # bias strip
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, o), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, fin), jnp.float32)],
        interpret=interpret,
    )(mask, xk, x, w_self, w_neigh, b)
